"""Data pipeline (partitioners, meta-set overlap control, cohort sampling)
and optimizer/schedule units."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; unit tests still run
    from _hypothesis_stub import given, settings, st

from repro.data.partition import (make_meta_set, partition_by_writer,
                                  partition_dirichlet, partition_iid)
from repro.data.pipeline import FederatedData
from repro.data.synthetic import (synthetic_chars, synthetic_images,
                                  synthetic_tokens)
from repro.optim import (cosine, linear_scaling_lr, wsd_schedule)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), clients=st.integers(2, 12))
def test_partition_iid_disjoint_complete(seed, clients):
    rng = np.random.default_rng(seed)
    parts = partition_iid(rng, 100, clients)
    allidx = np.concatenate(parts)
    assert len(allidx) == 100 and len(np.unique(allidx)) == 100


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50), alpha=st.sampled_from([0.1, 0.5, 5.0]))
def test_partition_dirichlet_valid(seed, alpha):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 400)
    parts = partition_dirichlet(rng, labels, 8, alpha=alpha, min_per_client=4)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == len(allidx) == 400
    assert min(len(p) for p in parts) >= 4


def test_partition_dirichlet_skew_increases_as_alpha_drops():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 5, 2000)

    def skew(alpha):
        parts = partition_dirichlet(np.random.default_rng(1), labels, 8,
                                    alpha=alpha)
        # mean per-client entropy of label distribution (lower = more skew)
        ents = []
        for p in parts:
            c = np.bincount(labels[p], minlength=5) + 1e-9
            q = c / c.sum()
            ents.append(-(q * np.log(q)).sum())
        return np.mean(ents)

    assert skew(0.1) < skew(10.0)


def test_by_writer_partition():
    w = np.array([0, 1, 0, 2, 1, 0])
    parts = partition_by_writer(w, [0, 1, 2])
    assert [len(p) for p in parts] == [3, 2, 1]


@pytest.mark.parametrize("overlap", [0.0, 0.25, 0.5, 1.0])
def test_meta_set_overlap_control(overlap):
    rng = np.random.default_rng(0)
    writers = np.repeat(np.arange(40), 25)           # 1000 examples
    train_w = list(range(20))
    aux_w = list(range(20, 40))
    meta = make_meta_set(rng, writers, train_w, aux_w, overlap=overlap,
                         fraction=0.05)
    meta_writers = set(writers[meta].tolist())
    frac_in_train = np.mean([w in set(train_w) for w in meta_writers])
    assert abs(frac_in_train - overlap) < 0.3


def test_cohort_sampling_shapes_and_weights():
    rng = np.random.default_rng(0)
    n = 200
    data = FederatedData(
        arrays={"x": rng.normal(size=(n, 3)).astype(np.float32)},
        client_indices=partition_iid(rng, n, 10),
        shared_indices=np.arange(16), seed=0)
    s = data.sample_round(3, cohort=4, batch=8)
    assert s["cohort_batch"]["x"].shape == (4, 8, 3)
    assert s["client_weights"].shape == (4,)
    assert len(set(s["clients"].tolist())) == 4
    # deterministic per round
    s2 = data.sample_round(3, cohort=4, batch=8)
    np.testing.assert_array_equal(s["cohort_batch"]["x"],
                                  s2["cohort_batch"]["x"])


def test_synthetic_generators_shapes():
    rng = np.random.default_rng(0)
    img = synthetic_images(rng, n=50, image_size=8, channels=3,
                           num_classes=4, num_writers=5)
    assert img.x.shape == (50, 8, 8, 3) and img.y.max() < 4
    ch = synthetic_chars(rng, n=20, seq_len=16, vocab=30, num_roles=5)
    assert ch.tokens.shape == (20, 16) and ch.tokens.max() < 30
    tk = synthetic_tokens(rng, n=20, seq_len=16, vocab=100, num_clients=4)
    assert tk.tokens.shape == (20, 16)


def test_wsd_schedule_shape():
    f = wsd_schedule(1.0, 1000, warmup_frac=0.01, decay_frac=0.1)
    assert float(f(0)) < 0.2
    assert abs(float(f(500)) - 1.0) < 1e-6           # stable plateau
    assert float(f(999)) < 0.2                       # decay tail
    g = cosine(1.0, 100, warmup=10)
    assert float(g(5)) < 1.0 and abs(float(g(10)) - 1.0) < 1e-5


def test_linear_scaling_rule():
    assert linear_scaling_lr(0.002, 128, 64) == pytest.approx(0.004)

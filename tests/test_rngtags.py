"""rng tag registry: uniqueness + bit-exact stream regression.

The pins below are inline literals ON PURPOSE: if anyone edits
``repro.core.rngtags`` the diff shows up here, and the stream tests prove
the centralization never reseeded a historical stream (every pre-registry
call site used exactly these constants inline).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rngtags
from repro.core.rngtags import TAGS, round_key
from repro.sim.faults import heavy_tail_speeds


def bits(k):
    """Raw uint32 words of a PRNG key, old- or new-style."""
    if jnp.issubdtype(k.dtype, jax.dtypes.prng_key):
        return np.asarray(jax.random.key_data(k))
    return np.asarray(k)


# ---------------------------------------------------------------------------
# registry integrity
# ---------------------------------------------------------------------------
def test_tags_are_globally_unique():
    assert len(set(TAGS.values())) == len(TAGS)


def test_tags_covers_every_exported_constant():
    assert TAGS == {
        "PARTICIPATION_FOLD": rngtags.PARTICIPATION_FOLD,
        "FAULT_FOLD": rngtags.FAULT_FOLD,
        "EVAL_FOLD": rngtags.EVAL_FOLD,
        "ROUND_OFFSET": rngtags.ROUND_OFFSET,
        "META_SAMPLE_SEED": rngtags.META_SAMPLE_SEED,
        "SPEED_SEED": rngtags.SPEED_SEED,
    }


def test_check_unique_raises_on_collision():
    saved = dict(TAGS)
    try:
        TAGS["SNEAKY_FOLD"] = rngtags.PARTICIPATION_FOLD
        with pytest.raises(ValueError, match="collision"):
            rngtags._check_unique()
    finally:
        TAGS.clear()
        TAGS.update(saved)
    rngtags._check_unique()                   # restored registry is clean


# ---------------------------------------------------------------------------
# historical values pinned bit-exact (the pre-registry inline constants)
# ---------------------------------------------------------------------------
def test_pinned_tag_values():
    assert rngtags.PARTICIPATION_FOLD == 0x5712A661
    assert rngtags.FAULT_FOLD == 0x00FA0175
    assert rngtags.EVAL_FOLD == 10_000
    assert rngtags.ROUND_OFFSET == 0
    assert rngtags.META_SAMPLE_SEED == 7_777
    assert rngtags.SPEED_SEED == 0x5BEED


def test_round_key_matches_historical_derivation():
    k = jax.random.PRNGKey(3)
    for r in (0, 1, 17, 4096):
        np.testing.assert_array_equal(
            bits(round_key(k, r)), bits(jax.random.fold_in(k, r)))


def test_registry_folds_match_inline_constants():
    k = jax.random.PRNGKey(11)
    np.testing.assert_array_equal(
        bits(jax.random.fold_in(k, rngtags.PARTICIPATION_FOLD)),
        bits(jax.random.fold_in(k, 0x5712A661)))
    np.testing.assert_array_equal(
        bits(jax.random.fold_in(k, rngtags.FAULT_FOLD)),
        bits(jax.random.fold_in(k, 0x00FA0175)))
    np.testing.assert_array_equal(
        bits(jax.random.fold_in(k, rngtags.EVAL_FOLD)),
        bits(jax.random.fold_in(k, 10_000)))


def test_host_streams_match_inline_seed_tuples():
    speeds = heavy_tail_speeds(5, 32)
    rng = np.random.default_rng((5, 0x5BEED))
    np.testing.assert_array_equal(
        speeds, np.exp(0.5 * rng.standard_normal(32)).astype(np.float32))

    # D_meta sampling stream (repro.data.pipeline.sample_meta)
    a = np.random.default_rng((9, rngtags.META_SAMPLE_SEED, 2)).integers(
        0, 1 << 30, 8)
    b = np.random.default_rng((9, 7_777, 2)).integers(0, 1 << 30, 8)
    np.testing.assert_array_equal(a, b)

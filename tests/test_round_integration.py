"""Federated-round integration: end-to-end convergence, algorithm ordering
on a synthetic non-IID problem, FedShare injection, checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs.base import FedConfig
from repro.core import init_server_state, make_federated_round
from repro.data.pipeline import FederatedData
from repro.data.partition import partition_dirichlet
from repro.models.model import Model


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(
            jnp.float32))
        return l, {"acc": acc}

    return Model(name="mlp", init=init, loss=loss)


def _noniid_problem(seed=0, n=512, d=10, classes=4, clients=16):
    rng = np.random.default_rng(seed)
    protos = rng.normal(0, 1, (classes, d))
    y = rng.integers(0, classes, n)
    x = protos[y] + 0.6 * rng.normal(0, 1, (n, d))
    parts = partition_dirichlet(rng, y, clients, alpha=0.3)
    meta = rng.choice(n, 32, replace=False)
    return FederatedData(
        arrays={"x": x.astype(np.float32), "y": y.astype(np.int32)},
        client_indices=parts, meta_indices=meta,
        shared_indices=rng.choice(n, 32, replace=False), seed=seed)


def _train(algorithm, meta, rounds=40, share=False, seed=0):
    model = make_mlp_model()
    data = _noniid_problem(seed)
    # UGA takes ONE server gradient step per round vs FedAvg's local_steps
    # biased ones — eta_g = local_steps * eta equalizes the per-round step
    # budget at this tiny round count (the paper fixes eta_g=eta over 500+
    # rounds; see benchmarks/common.py)
    fed = FedConfig(algorithm=algorithm, meta=meta, share=share, cohort=4,
                    local_steps=4, client_lr=0.1, server_lr=0.4, meta_lr=0.1)
    rf = jax.jit(make_federated_round(model, fed))
    key = jax.random.PRNGKey(seed)
    state = init_server_state(model, fed, key)
    for r in range(rounds):
        s = data.sample_round(r, cohort=4, batch=16, share=share)
        meta_b = data.sample_meta(r, 16)
        state, m = rf(state, jax.tree.map(jnp.asarray, s["cohort_batch"]),
                      jax.tree.map(jnp.asarray, meta_b),
                      jnp.asarray(s["client_weights"]),
                      jax.random.fold_in(key, r))
    # full-data eval
    full = {"x": jnp.asarray(data.arrays["x"]),
            "y": jnp.asarray(data.arrays["y"])}
    return float(model.loss(state["params"], full)[0]), state


def test_uga_meta_converges_and_beats_fedavg():
    l_uga, _ = _train("uga", meta=True)
    l_avg, _ = _train("fedavg", meta=False)
    l_init = 1.6  # ~ln(4) + slack
    assert l_uga < l_init * 0.7, l_uga          # converges
    assert l_avg < l_init * 0.9, l_avg          # baseline converges too
    # at comparable per-round step budgets UGA+meta is at least in the same
    # ballpark (the ordering claims are benchmarked, not unit-tested)
    assert l_uga < l_avg * 1.5, (l_uga, l_avg)


def test_fedprox_runs_and_converges():
    l, _ = _train("fedprox", meta=False)
    assert l < 1.3


def test_fedshare_injection_changes_batches():
    data = _noniid_problem()
    a = data.sample_round(0, cohort=4, batch=16, share=False)
    b = data.sample_round(0, cohort=4, batch=16, share=True,
                          share_fraction=0.5)
    assert a["cohort_batch"]["x"].shape == b["cohort_batch"]["x"].shape
    assert not np.allclose(a["cohort_batch"]["x"], b["cohort_batch"]["x"])


def test_fedshare_without_shared_indices_raises():
    """share=True with no FedShare global set used to silently return
    batches of size batch - n_share — a shape mismatch far downstream.
    It must raise at the call site instead."""
    data = _noniid_problem()
    data.shared_indices = None
    with pytest.raises(ValueError, match="shared_indices"):
        data.sample_round(0, cohort=4, batch=16, share=True)
    # share_fraction=0 degenerates to no injection: still fine
    s = data.sample_round(0, cohort=4, batch=16, share=True,
                          share_fraction=0.0)
    assert s["cohort_batch"]["x"].shape[1] == 16


def test_lr_decay_applied():
    # fedavg: the pseudo-gradient scales with the (decayed) client lr.
    # (UGA's server step uses the non-decayed eta_g by design — Eq. 14.)
    model = make_mlp_model()
    fed = FedConfig(algorithm="fedavg", meta=False, cohort=2, local_steps=2,
                    client_lr=0.1, lr_decay=0.5)
    rf = jax.jit(make_federated_round(model, fed))
    key = jax.random.PRNGKey(0)
    data = _noniid_problem()
    s0 = init_server_state(model, fed, key)
    # round index deep in training => tiny effective lr => tiny grad step
    s_late = dict(s0, round=jnp.asarray(50, jnp.int32))
    smp = data.sample_round(0, cohort=2, batch=8)
    args = (jax.tree.map(jnp.asarray, smp["cohort_batch"]),
            jax.tree.map(jnp.asarray, data.sample_meta(0, 8)),
            jnp.asarray(smp["client_weights"]), key)
    s1, _ = rf(s0, *args)
    s2, _ = rf(s_late, *args)
    d_early = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(s1["params"]), jax.tree.leaves(s0["params"])))
    d_late = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(s2["params"]), jax.tree.leaves(s0["params"])))
    assert d_late < d_early * 0.05


@pytest.mark.parametrize("opt", ["sgd", "sgdm", "adam", "yogi"])
def test_server_optimizers_run(opt):
    model = make_mlp_model()
    fed = FedConfig(algorithm="uga", meta=False, cohort=2, local_steps=2,
                    client_lr=0.05, server_opt=opt, server_momentum=0.9)
    rf = jax.jit(make_federated_round(model, fed))
    key = jax.random.PRNGKey(0)
    data = _noniid_problem()
    state = init_server_state(model, fed, key)
    smp = data.sample_round(0, cohort=2, batch=8)
    state, m = rf(state, jax.tree.map(jnp.asarray, smp["cohort_batch"]),
                  jax.tree.map(jnp.asarray, data.sample_meta(0, 8)),
                  jnp.asarray(smp["client_weights"]), key)
    assert bool(jnp.isfinite(m["client_loss"]))


def test_checkpoint_roundtrip(tmp_path):
    model = make_mlp_model()
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    path = os.path.join(tmp_path, "ck.msgpack")
    save(path, params, extra={"round": 7})
    restored, extra = restore(path, params)
    assert extra["round"] == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

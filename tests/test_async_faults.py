"""Fault-tolerant async federation runtime (PR 6):

  * buffered_async bit-identity: a FAULT-FREE tick with K = capacity =
    cohort over the scan base reproduces the synchronous fused-scan round
    bit-exactly (params + opt + meta), and tracks the vmap base to fp32
    reduction tolerance;
  * fault determinism: the seeded fault streams are pure functions of the
    round rng (invariant to rounds_per_call chunking, distinct per round);
  * EF interaction: a crashed/dropped client's ``state["comm"]`` residual
    slot stays byte-identical (it never transmitted);
  * degradation policy: an all-dropped round (participation mask or
    faults) leaves params/opt bit-unchanged on every executor x engine,
    the trainer's retry-with-backoff re-enqueues failed clients, and
    ``sample_round(include=...)`` lands them without perturbing the
    retry-free sampling streams;
  * crash-safe checkpointing: a failed save leaves the previous
    checkpoint restorable (atomic rename, no temp litter), and truncated /
    corrupted blobs fail with errors naming the path and what was
    expected; a mid-run async save/resume (pool + staleness counters
    included) is bit-identical to never stopping;
  * config guards: K > capacity deadlock, round_deadline under async,
    explicit garble on a sync engine, unknown staleness_mode.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore as ckpt_restore
from repro.checkpoint import save as ckpt_save
from repro.configs.base import FedConfig
from repro.core import (FederatedTrainer, init_server_state,
                        make_federated_round, staleness_discount)
from repro.data.pipeline import FederatedData
from repro.models.model import Model
from repro.sim.faults import (FAULT_PROFILES, FaultConfig, fault_streams,
                              heavy_tail_speeds, resolve_faults)

COHORT, BATCH = 4, 16


def make_mlp_model(d=10, h=16, classes=4):
    def init(k):
        k1, k2 = jax.random.split(k)
        return {"w1": jax.random.normal(k1, (d, h)) * 0.3,
                "w2": jax.random.normal(k2, (h, classes)) * 0.3}

    def loss(w, batch, rng=None):
        logits = jnp.tanh(batch["x"] @ w["w1"]) @ w["w2"]
        l = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits), batch["y"][:, None], 1))
        return l, {}

    return Model(name="mlp", init=init, loss=loss)


def _round_inputs(seed=0, cohort=COHORT, b=BATCH):
    rng = np.random.default_rng(seed)
    batch = {"x": jnp.asarray(rng.normal(0, 1, (cohort, b, 10)),
                              jnp.float32),
             "y": jnp.asarray(rng.integers(0, 4, (cohort, b)), jnp.int32)}
    meta = {"x": jnp.asarray(rng.normal(0, 1, (8, 10)), jnp.float32),
            "y": jnp.asarray(rng.integers(0, 4, 8), jnp.int32)}
    wts = jnp.asarray(rng.uniform(1.0, 5.0, cohort), jnp.float32)
    return batch, meta, wts


def tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _toy_fed_data(n=256, clients=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, 10)).astype(np.float32)
    y = rng.integers(0, 4, n).astype(np.int32)
    parts = np.array_split(rng.permutation(n), clients)
    meta = rng.choice(n, 32, replace=False)
    return FederatedData(arrays={"x": x, "y": y}, client_indices=parts,
                         meta_indices=meta, seed=seed)


def _run_rounds(model, fed, rounds, seed=1, **mk_kwargs):
    state = init_server_state(model, fed, jax.random.PRNGKey(seed),
                              engine=mk_kwargs.get("engine"))
    fn = jax.jit(make_federated_round(model, fed, **mk_kwargs))
    key = jax.random.PRNGKey(0)
    metrics = None
    for r in range(rounds):
        batch, meta, wts = _round_inputs(seed=r)
        state, metrics = fn(state, batch, meta, wts,
                            jax.random.fold_in(key, r))
    return state, metrics


# ---------------------------------------------------------------------------
# bit-identity of the fault-free async tick
# ---------------------------------------------------------------------------
def test_async_cleanroom_bit_identical_to_sync_scan():
    model = make_mlp_model()
    fed_sync = FedConfig(cohort=COHORT, fused_update=True,
                         cohort_strategy="scan", server_opt="adam",
                         meta=True)
    fed_async = dataclasses.replace(fed_sync, engine="buffered_async",
                                    async_buffer=COHORT,
                                    async_capacity=COHORT)
    s_sync, m_sync = _run_rounds(model, fed_sync, 3)
    s_async, m_async = _run_rounds(model, fed_async, 3)
    assert tree_equal(s_sync["params"], s_async["params"])
    assert tree_equal(s_sync["opt"], s_async["opt"])
    assert np.array_equal(np.asarray(m_sync["client_loss"]),
                          np.asarray(m_async["client_loss"]))
    assert np.array_equal(np.asarray(m_sync["meta_loss"]),
                          np.asarray(m_async["meta_loss"]))
    assert float(m_async["server_steps"]) == 1.0
    assert float(m_async["arrivals"]) == COHORT


def test_async_cleanroom_tracks_vmap_base():
    model = make_mlp_model()
    fed_sync = FedConfig(cohort=COHORT, fused_update=True,
                         cohort_strategy="vmap", meta=False)
    fed_async = dataclasses.replace(fed_sync, engine="buffered_async",
                                    async_buffer=COHORT,
                                    async_capacity=COHORT)
    s_sync, _ = _run_rounds(model, fed_sync, 2)
    s_async, _ = _run_rounds(model, fed_async, 2)
    # the vmap executor aggregates in parallel (flat_weighted_aggregate)
    # while the pool flush streams sequentially: same math, different
    # reduction order -> fp32 tolerance, not bit-identity
    for a, b in zip(jax.tree.leaves(s_sync["params"]),
                    jax.tree.leaves(s_async["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# fault streams: deterministic, chunk-invariant
# ---------------------------------------------------------------------------
def test_fault_streams_deterministic_and_per_round():
    fc = resolve_faults(FedConfig(fault_profile="flaky"))
    assert fc.active
    k0 = jax.random.fold_in(jax.random.PRNGKey(7), 0)
    k1 = jax.random.fold_in(jax.random.PRNGKey(7), 1)
    a, b = fault_streams(k0, 16, fc), fault_streams(k0, 16, fc)
    assert tree_equal(a, b)
    c = fault_streams(k1, 16, fc)
    assert not np.array_equal(np.asarray(a.latency), np.asarray(c.latency))
    # ungarbled multipliers are EXACTLY 1.0 (IEEE identity on the deltas)
    mult = np.asarray(a.garble_mult)
    garbled = np.asarray(a.garbled)
    assert np.all(mult[~garbled] == 1.0)
    # crashed and dropped are disjoint
    assert not np.any(np.asarray(a.crashed) & np.asarray(a.dropped))


@pytest.mark.parametrize("engine", [None, "buffered_async"])
def test_faulty_run_chunking_invariant(engine):
    """rounds_per_call=1 vs 3 under the flaky profile: fault streams fold
    off per-round rngs, so chunking cannot perturb them (sync AND async)."""
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=True,
                    fault_profile="flaky", engine=engine,
                    async_capacity=2 * COHORT if engine else 0)
    data = _toy_fed_data()
    final = []
    for k in (1, 3):
        tr = FederatedTrainer(model, fed, rounds_per_call=k, seed=0)
        tr.run(data, rounds=6, cohort=COHORT, batch=8, meta_batch=8)
        final.append(tr.state)
    assert tree_equal(final[0], final[1])


# ---------------------------------------------------------------------------
# EF residuals under faults
# ---------------------------------------------------------------------------
def test_crashed_client_residual_byte_identical():
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=False,
                    engine="buffered_async", async_buffer=2,
                    async_capacity=2 * COHORT, codec="int8",
                    error_feedback=True, fault_crash=0.6, fault_drop=0.2)
    state = init_server_state(model, fed, jax.random.PRNGKey(1),
                              engine="buffered_async")
    fn = jax.jit(make_federated_round(model, fed))
    faults = resolve_faults(fed)
    key = jax.random.PRNGKey(0)
    saw_failed = False
    for r in range(4):
        batch, meta, wts = _round_inputs(seed=r)
        rng = jax.random.fold_in(key, r)
        fs = fault_streams(rng, COHORT, faults)
        res_before = [np.asarray(g) for g in state["comm"]["residual"]]
        state, _ = fn(state, batch, meta, wts, rng)
        failed = ~np.asarray(fs.alive, bool)
        saw_failed = saw_failed or failed.any()
        for gb, ga in zip(res_before, state["comm"]["residual"]):
            # a client that never transmitted keeps its EF memory bitwise
            assert np.array_equal(gb[failed], np.asarray(ga)[failed])
            if (~failed).any() and r > 0:
                assert not np.array_equal(gb[~failed],
                                          np.asarray(ga)[~failed])
    assert saw_failed  # crash=0.6 over 4 rounds x 4 clients: certain-ish


# ---------------------------------------------------------------------------
# degradation policy: empty-cohort rounds, retry, include=
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy,fused", [("vmap", True), ("scan", True),
                                            ("vmap", False)])
def test_all_dropped_round_is_noop_server_step(strategy, fused):
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=fused,
                    cohort_strategy=strategy, meta=True,
                    participation=0.05)
    state = init_server_state(model, fed, jax.random.PRNGKey(1))
    fn = jax.jit(make_federated_round(model, fed))
    from repro.core import participation_mask
    key = jax.random.PRNGKey(0)
    hit = False
    for r in range(40):
        batch, meta, wts = _round_inputs(seed=r)
        rng = jax.random.fold_in(key, r)
        mask = participation_mask(rng, COHORT, fed.participation)
        before = jax.tree.map(np.asarray, state)
        state, metrics = fn(state, batch, meta, wts, rng)
        if float(jnp.sum(mask)) == 0:
            hit = True
            assert tree_equal(before["params"], state["params"])
            assert tree_equal(before["opt"], state["opt"])
            assert float(metrics["participants"]) == 0
            assert float(metrics["meta_loss"]) == 0
            assert int(state["round"]) == int(before["round"]) + 1
    assert hit, "participation=0.05 never produced an all-dropped round"


def test_sample_round_include_semantics():
    data = _toy_fed_data()
    base = data.sample_round(3, cohort=COHORT, batch=8)
    again = data.sample_round(3, cohort=COHORT, batch=8, include=None)
    empty = data.sample_round(3, cohort=COHORT, batch=8, include=[])
    assert tree_equal(base, again) and tree_equal(base, empty)
    # force specific clients in: they land, cohort size unchanged
    want = [c for c in range(data.num_clients)
            if c not in set(base["clients"].tolist())][:2]
    inc = data.sample_round(3, cohort=COHORT, batch=8, include=want)
    assert set(want) <= set(inc["clients"].tolist())
    assert len(inc["clients"]) == COHORT
    assert len(set(inc["clients"].tolist())) == COHORT


def test_trainer_retry_reenqueues_failed_clients():
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=False,
                    fault_crash=0.5, fault_max_delay=0,
                    retry_backoff=1, retry_max=2)
    data = _toy_fed_data()
    tr = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    hist = tr.run(data, rounds=8, cohort=COHORT, batch=8)
    assert all("retried" in h for h in hist)
    assert sum(h["retried"] for h in hist) > 0
    # the policy is deterministic: an identical run retries identically
    tr2 = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    hist2 = tr2.run(data, rounds=8, cohort=COHORT, batch=8)
    assert [h["retried"] for h in hist] == [h["retried"] for h in hist2]
    assert tree_equal(tr.state, tr2.state)


def test_client_speeds_ship_with_sample():
    data = _toy_fed_data()
    speeds = heavy_tail_speeds(0, data.num_clients)
    assert speeds.shape == (data.num_clients,) and (speeds > 0).all()
    data.client_speeds = speeds
    s = data.sample_round(0, cohort=COHORT, batch=8)
    assert np.array_equal(s["client_speeds"], speeds[s["clients"]])


# ---------------------------------------------------------------------------
# async runtime metrics + staleness machinery
# ---------------------------------------------------------------------------
def test_async_metrics_and_staleness_histogram():
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=True,
                    engine="buffered_async", async_buffer=2,
                    async_capacity=2 * COHORT, fault_profile="flaky")
    data = _toy_fed_data()
    tr = FederatedTrainer(model, fed, rounds_per_call=2, seed=0)
    hist = tr.run(data, rounds=4, cohort=COHORT, batch=8, meta_batch=8)
    for h in hist:
        assert isinstance(h["staleness_hist"], list)
        assert len(h["staleness_hist"]) == 8
        for k in ("arrivals", "server_steps", "buffer_fill",
                  "overflow_dropped", "staleness_mean", "staleness_max",
                  "fault_crashed", "fault_dropped", "fault_delayed"):
            assert isinstance(h[k], float), k
    assert sum(h["arrivals"] for h in hist) > 0


def test_staleness_discount_modes():
    z = jnp.float32(0.0)
    for mode in ("none", "inv", "invsqrt"):
        assert float(staleness_discount(mode)(z)) == 1.0
    assert float(staleness_discount("inv")(jnp.float32(3.0))) == 0.25
    with pytest.raises(ValueError, match="staleness_mode"):
        staleness_discount("quadratic")


def test_async_max_staleness_evicts():
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=False,
                    engine="buffered_async", async_buffer=2,
                    async_capacity=2 * COHORT, async_max_staleness=1,
                    fault_profile="flaky")
    _, metrics = _run_rounds(model, fed, 5)
    assert "expired" in metrics
    assert np.isfinite(float(metrics["expired"]))


# ---------------------------------------------------------------------------
# config guards
# ---------------------------------------------------------------------------
def test_async_deadlock_and_deadline_config_errors():
    with pytest.raises(ValueError, match="deadlock"):
        FedConfig(engine="buffered_async", fused_update=True,
                  async_buffer=9, async_capacity=4)
    with pytest.raises(ValueError, match="async_max_staleness"):
        FedConfig(engine="buffered_async", fused_update=True,
                  round_deadline=2.0)
    with pytest.raises(ValueError, match="staleness_mode"):
        FedConfig(staleness_mode="quadratic")
    with pytest.raises(ValueError, match="fault_profile"):
        FedConfig(fault_profile="catastrophic")
    with pytest.raises(ValueError, match="fault_crash"):
        FedConfig(fault_crash=1.5)
    with pytest.raises(ValueError, match="fault_max_delay"):
        FedConfig(fault_delay=0.5)


def test_explicit_garble_requires_async_engine():
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True, fault_garble=0.3)
    with pytest.raises(ValueError, match="buffered_async"):
        make_federated_round(model, fed)
    # profile-carried garble downgrades silently on sync engines...
    fed_prof = FedConfig(cohort=COHORT, fused_update=True,
                         cohort_strategy="scan", meta=False,
                         fault_profile="flaky")
    _run_rounds(make_mlp_model(), fed_prof, 1)
    # ...and garble runs fine under the async runtime
    fed_async = dataclasses.replace(fed, engine="buffered_async",
                                    cohort_strategy="scan", meta=False,
                                    async_capacity=2 * COHORT)
    state, _ = _run_rounds(model, fed_async, 2)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(state))


def test_fault_profiles_registry():
    assert set(FAULT_PROFILES) >= {"none", "flaky", "stragglers"}
    assert not resolve_faults(FedConfig()).active
    fc = resolve_faults(FedConfig(fault_profile="flaky", fault_crash=0.5))
    assert fc.crash == 0.5 and fc.drop == FAULT_PROFILES["flaky"]["drop"]
    assert FaultConfig(delay=0.5, max_delay=2).active


# ---------------------------------------------------------------------------
# crash-safe checkpointing + async save/resume
# ---------------------------------------------------------------------------
def test_async_save_resume_bit_identical(tmp_path):
    model = make_mlp_model()
    fed = FedConfig(cohort=COHORT, fused_update=True,
                    cohort_strategy="scan", meta=True,
                    engine="buffered_async", async_buffer=2,
                    async_capacity=2 * COHORT, fault_profile="flaky")
    data = _toy_fed_data()
    ref = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    ref.run(data, rounds=6, cohort=COHORT, batch=8, meta_batch=8)

    tr = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    tr.run(data, rounds=3, cohort=COHORT, batch=8, meta_batch=8)
    assert float(jnp.sum(tr.state["async"]["weight"])) > 0, \
        "pool should hold pending deltas mid-run for the resume to matter"
    path = str(tmp_path / "async.ckpt")
    tr.save(path)
    tr2 = FederatedTrainer(model, fed, rounds_per_call=1, seed=0)
    tr2.restore(path)
    tr2.run(data, rounds=6, cohort=COHORT, batch=8, meta_batch=8)
    assert tree_equal(ref.state, tr2.state)  # pool + staleness included


def test_ckpt_corrupt_blob_actionable(tmp_path):
    path = str(tmp_path / "state.ckpt")
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt_save(path, tree)
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[:len(blob) // 2])
    with pytest.raises(ValueError) as ei:
        ckpt_restore(path, tree)
    msg = str(ei.value)
    assert path in msg and ("msgpack" in msg or "truncated" in msg)
    # a decodable blob that is not a checkpoint payload
    import msgpack
    with open(path, "wb") as f:
        f.write(msgpack.packb({"not": "a checkpoint"}))
    with pytest.raises(ValueError, match="leaves"):
        ckpt_restore(path, tree)


def test_ckpt_failed_save_preserves_previous(tmp_path, monkeypatch):
    path = str(tmp_path / "state.ckpt")
    tree0 = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt_save(path, tree0, extra={"gen": 0})

    import repro.checkpoint.ckpt as ckpt_mod

    def boom(*a, **kw):
        raise RuntimeError("disk full (simulated)")
    monkeypatch.setattr(ckpt_mod.msgpack, "packb", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        ckpt_save(path, {"w": jnp.zeros(8, jnp.float32)}, extra={"gen": 1})
    monkeypatch.undo()
    # the previous checkpoint survives a mid-write failure, intact
    restored, extra = ckpt_restore(path, tree0)
    assert extra == {"gen": 0}
    assert np.array_equal(np.asarray(restored["w"]), np.arange(8))
    # and no temp litter for a retry to trip over
    assert [f for f in os.listdir(tmp_path) if ".tmp" in f] == []

"""Unit tests for the communication-compression subsystem (repro.comm):

  * Pallas pack/unpack kernels == pure-jnp ref oracles (bit-exact for the
    integer stages, fp32-exact for the FMA stages), on buffers WITH layout
    padding so the pad-inertness convention is exercised;
  * per-codec round-trip properties: quantization error bounds (int8),
    two-point alphabet + strict contraction (sign1bit), exact top-k
    support recovery (topk), exact identity (none);
  * the fused encode_ef sweep == the generic encode/decode residual
    definition for every codec;
  * measured payload bytes match the transport arithmetic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codecs import (Int8Codec, NoneCodec, Sign1BitCodec,
                               TopKCodec, available_codecs, get_codec)
from repro.core.flat import LANES, flatten_tree, make_flat_spec
from repro.kernels.comm import kernel as K
from repro.kernels.comm import ref as R

# a mixed-shape tree whose single fp32 group pads 68 -> 8*128 elements,
# so every test below covers real layout padding
TREE = {"a": jnp.zeros((5, 7), jnp.float32), "b": jnp.zeros((33,),
                                                            jnp.float32)}
SPEC = make_flat_spec(TREE)
GROUP = SPEC.groups[0]


def rand_group(seed=0, scale=1.0):
    """(rows, LANES) fp32 buffer with the group's pad zeroed, like every
    real flatten_tree output."""
    rng = np.random.default_rng(seed)
    tree = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(0, scale, x.shape), jnp.float32),
        TREE)
    return flatten_tree(SPEC, tree)[0]


def valid_mask():
    flat_idx = np.arange(GROUP.rows * LANES).reshape(GROUP.rows, LANES)
    return flat_idx < GROUP.size


# ---------------------------------------------------------------------------
# kernels == ref oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("with_error", [False, True])
def test_quantize_i8_kernel_matches_ref(with_error):
    g = rand_group(1)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    out_k = K.quantize_i8_pass(g, 1.0 / scale, scale,
                               with_error=with_error, interpret=True)
    out_r = R.quantize_i8_ref(g, 1.0 / scale, scale, with_error=with_error)
    if with_error:
        np.testing.assert_array_equal(np.asarray(out_k[0]),
                                      np.asarray(out_r[0]))
        # the error output is fp32: interpret-mode Pallas may contract the
        # g - q*scale FMA differently from plain jnp (~1 ulp)
        np.testing.assert_allclose(np.asarray(out_k[1]),
                                   np.asarray(out_r[1]),
                                   rtol=1e-6, atol=1e-7)
    else:
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        assert np.asarray(out_k).dtype == np.int8


def test_dequant_i8_fma_kernel_matches_ref():
    g = rand_group(2)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    q = R.quantize_i8_ref(g, 1.0 / scale, scale)
    acc = rand_group(3)
    out_k = K.dequant_i8_fma_pass(acc, q, scale * 0.37, interpret=True)
    out_r = R.dequant_i8_fma_ref(acc, q, scale * 0.37)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("with_error", [False, True])
def test_sign_pack_kernel_matches_ref(with_error):
    g = rand_group(4)
    mu = float(jnp.sum(jnp.abs(g))) / GROUP.size
    out_k = K.sign_pack_pass(g, mu, GROUP.size, with_error=with_error,
                             interpret=True)
    out_r = R.sign_pack_ref(g, mu, GROUP.size, with_error=with_error)
    if with_error:
        np.testing.assert_array_equal(np.asarray(out_k[0]),
                                      np.asarray(out_r[0]))
        np.testing.assert_array_equal(np.asarray(out_k[1]),
                                      np.asarray(out_r[1]))
    else:
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        assert np.asarray(out_k).dtype == np.uint8
        assert out_k.shape == (GROUP.rows // K.SIGN_PACK, LANES)


def test_sign_unpack_fma_kernel_matches_ref():
    g = rand_group(5)
    packed = R.sign_pack_ref(g, 1.0, GROUP.size)
    acc = rand_group(6)
    out_k = K.sign_unpack_fma_pass(acc, packed, 0.21, GROUP.size,
                                   interpret=True)
    out_r = R.sign_unpack_fma_ref(acc, packed, 0.21, GROUP.size)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_sign_pack_unpack_roundtrip_and_pad_inert():
    """pack -> unpack recovers sign(g) * mu on the valid elements and EXACT
    zero on the layout pad (the invariant flat_sq_norm / opt slots / EF
    state rely on)."""
    g = rand_group(7)
    packed = R.sign_pack_ref(g, 1.0, GROUP.size)
    dec = np.asarray(K.sign_unpack_fma_pass(
        jnp.zeros_like(g), packed, 0.5, GROUP.size, interpret=True))
    m = valid_mask()
    expect = np.where(np.asarray(g) >= 0, 0.5, -0.5)
    np.testing.assert_array_equal(dec[m], expect[m])
    np.testing.assert_array_equal(dec[~m], 0.0)


# ---------------------------------------------------------------------------
# per-codec round-trip properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_int8_roundtrip_error_bound(seed):
    """Symmetric round-to-nearest: |decode(encode(g)) - g| <= scale / 2
    everywhere (amax maps to exactly 127, so clipping never adds error)."""
    codec = Int8Codec()
    g = rand_group(seed, scale=0.5)
    p = codec.encode(GROUP, g)
    dec = codec.decode(GROUP, p)
    scale = float(p["scale"])
    err = np.abs(np.asarray(dec) - np.asarray(g))
    assert err.max() <= scale / 2 * (1 + 1e-5)
    np.testing.assert_array_equal(np.asarray(dec)[~valid_mask()], 0.0)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sign1bit_roundtrip_alphabet_and_contraction(seed):
    """decode is the two-point alphabet {-mu, +mu} with g's signs, and the
    compression error strictly contracts: ||g - dec||^2 = ||g||^2 - n*mu^2
    < ||g||^2 (the EF convergence ingredient)."""
    codec = Sign1BitCodec()
    g = rand_group(seed)
    dec = np.asarray(codec.decode(GROUP, codec.encode(GROUP, g)))
    mu = float(jnp.sum(jnp.abs(g))) / GROUP.size
    m = valid_mask()
    np.testing.assert_allclose(dec[m],
                               np.where(np.asarray(g)[m] >= 0, mu, -mu),
                               rtol=1e-6)
    np.testing.assert_array_equal(dec[~m], 0.0)
    gn = np.asarray(g)
    assert np.linalg.norm(gn - dec) < np.linalg.norm(gn)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_topk_roundtrip_support_recovery(seed):
    """decode equals g exactly on the k largest-|g| elements, zero off the
    support, so the error never exceeds ||g||."""
    class FedStub:
        topk_ratio = 0.05
    codec = TopKCodec(FedStub())
    g = rand_group(seed)
    k = codec._k(GROUP)
    dec = np.asarray(codec.decode(GROUP, codec.encode(GROUP, g)))
    gn = np.asarray(g)
    kept = np.argsort(-np.abs(gn).reshape(-1))[:k]
    np.testing.assert_array_equal(dec.reshape(-1)[kept],
                                  gn.reshape(-1)[kept])
    off = np.setdiff1d(np.arange(gn.size), kept)
    np.testing.assert_array_equal(dec.reshape(-1)[off], 0.0)
    assert np.linalg.norm(gn - dec) <= np.linalg.norm(gn)


def test_none_codec_identity():
    codec = NoneCodec()
    g = rand_group(9)
    assert not codec.lossy
    np.testing.assert_array_equal(
        np.asarray(codec.decode(GROUP, codec.encode(GROUP, g))),
        np.asarray(g))


@pytest.mark.parametrize("codec_cls", [Int8Codec, Sign1BitCodec, TopKCodec])
def test_encode_ef_matches_generic_residual(codec_cls):
    """The fused encode+error sweep must equal the definitional residual
    e - decode(encode(e)) — the gate that keeps the one-sweep EF kernels
    honest against the generic GradientCodec contract."""
    codec = codec_cls()
    e = rand_group(11)
    payload, res = codec.encode_ef(GROUP, e)
    dec = codec.decode(GROUP, payload)
    np.testing.assert_allclose(np.asarray(res),
                               np.asarray(e) - np.asarray(dec),
                               rtol=1e-6, atol=1e-7)


def test_payload_bytes_arithmetic():
    assert NoneCodec().payload_bytes(GROUP) == 4 * GROUP.size
    assert Int8Codec().payload_bytes(GROUP) == GROUP.size + 4
    assert Sign1BitCodec().payload_bytes(GROUP) == -(-GROUP.size // 8) + 4

    class FedStub:
        topk_ratio = 0.1
    tk = TopKCodec(FedStub())
    assert tk.payload_bytes(GROUP) == 8 * max(1, round(GROUP.size * 0.1))


def test_registry_names_and_unknown_error():
    assert set(available_codecs()) >= {"none", "int8", "sign1bit", "topk"}
    with pytest.raises(ValueError, match="register_codec"):
        get_codec("zstd")

"""FedAgg-style adaptive per-client aggregation weights (arXiv:2303.15799)
as a ONE-FILE ClientAlgorithm plugin — no core edits.

FedAgg adapts each client's aggregation weight to how far its local model
has drifted from the global one, damping divergent (non-IID / noisy)
clients instead of trusting raw sample counts.  The registries aggregate
``G_k`` under fixed ``n_k`` weights, so the adaptive weight folds into the
update itself: the client rescales its pseudo-gradient by

    a_k = 1 / (1 + ALPHA * ||w_t - w_k||)

— a per-client trust coefficient computable locally (clients never see
each other), which is exactly how FedAgg keeps the scheme one-round.  The
weighted mean of ``a_k * G_k`` under ``n_k`` IS the adaptive-weight
aggregate up to the shared normalization.

Run it straight from the CLI (the --plugin flag imports this module before
--algorithm's choices freeze), composing with any cohort executor, server
engine AND gradient codec — e.g. adaptive weighting under an int8 uplink
with error feedback:

  PYTHONPATH=src:. python -m repro.launch.train \
      --plugin examples.plugins.fedagg --algorithm fedagg \
      --arch smollm-360m-smoke --rounds 3 --cohort 2 --client-batch 4 \
      --seq 32 --no-meta --fused --codec int8 --error-feedback
"""
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.algorithms import register_algorithm
from repro.core.client import fedavg_update

# drift-damping strength: a_k = 1 / (1 + ALPHA * ||delta_k||); 0 recovers
# fedavg exactly
ALPHA = 1.0


def fedagg_update(loss_fn, w_t, batch, lr, rng=None, *, local_steps=2,
                  local_epochs=1, prox_mu=0.0, remat=True):
    pseudo, loss = fedavg_update(loss_fn, w_t, batch, lr, rng,
                                 local_steps=local_steps,
                                 local_epochs=local_epochs, prox_mu=prox_mu,
                                 remat=remat)
    # pseudo = w_t - w_k, so its norm IS the local drift ||w_t - w_k||
    drift = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(pseudo)))
    a_k = 1.0 / (1.0 + ALPHA * drift)
    return jax.tree.map(lambda g: a_k * g, pseudo), loss


@register_algorithm("fedagg", pseudo_gradient=True,
                    description="adaptive drift-damped per-client weights "
                                "(FedAgg, arXiv:2303.15799)")
def build_fedagg(loss_fn, *, local_steps, local_epochs, prox_mu, remat):
    return partial(fedagg_update, loss_fn, local_steps=local_steps,
                   local_epochs=local_epochs, prox_mu=prox_mu, remat=remat)

"""Controllable federated models (§3.2.2 / Fig. 5 demo).

Two client populations write in different "styles" (synthetic non-IID
images).  The training cohort only ever contains population A; the server's
meta set D_meta is drawn from population B — the deployment target.  With
FedMeta the global model is steered toward B *without any B client ever
training*; vanilla FedAvg can only fit A.

    PYTHONPATH=src python examples/controllable_meta.py
"""
import dataclasses
import os
import sys

import numpy as np

# benchmarks/ lives at the repo root (next to examples/), not under src/
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import train_method  # noqa: E402
from repro.configs import paper_models as pm
from repro.data.partition import partition_by_writer
from repro.data.pipeline import FederatedData
from repro.data.synthetic import synthetic_images
from repro.models.model import build_paper_cnn


def main():
    rng = np.random.default_rng(0)
    writers = 16
    ds = synthetic_images(rng, n=1600, image_size=14, channels=1,
                          num_classes=10, num_writers=2 * writers,
                          style_strength=0.9)
    pop_a = list(range(writers))                 # training clients
    pop_b = list(range(writers, 2 * writers))    # deployment target
    parts = [p if p.size else np.array([0])
             for p in partition_by_writer(ds.writer, pop_a)]
    b_idx = np.where(np.isin(ds.writer, pop_b))[0]
    meta = rng.choice(b_idx, 32, replace=False)              # D_meta ~ B !
    eval_b = np.setdiff1d(b_idx, meta)[:256]

    data = FederatedData(arrays={"x": ds.x, "y": ds.y},
                         client_indices=parts, meta_indices=meta,
                         shared_indices=meta.copy(), seed=0)
    cfg = dataclasses.replace(pm.FEMNIST_CNN_SMOKE, image_size=14,
                              num_classes=10)
    model = build_paper_cnn(cfg)

    for method in ("fedavg", "fedmeta"):
        hist = train_method(model, data, method, rounds=25, cohort=4,
                            batch=16, local_steps=2, lr=0.05,
                            eval_idx=eval_b, eval_every=5)
        print(f"{method:8s} accuracy on TARGET population B: "
              f"{hist[-1]['acc']:.3f}")
    print("\nFedMeta steers the federated model toward D_meta's population "
          "— the paper's 'controllable federated models' in action.")


if __name__ == "__main__":
    main()

"""Batched serving demo: prefill a batch of prompts, decode with the KV
cache, report tokens/s — including the sliding-window ring-buffer cache.

    PYTHONPATH=src python examples/serve_batch.py [--arch smollm-360m-smoke]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.serve import generate
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)),
                          jnp.int32)

    # full-cache decode
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    toks, stats = generate(model, params, prompts, gen_len=args.gen,
                           cache_len=args.prompt_len + args.gen + 1)
    print(f"full cache   : {stats['tok_per_s']:7.1f} tok/s, "
          f"first row {np.asarray(toks[0])[:8].tolist()}")

    # sliding-window ring-buffer decode (the long_500k variant, small here)
    W = max(cfg.sliding_window, 16) if cfg.sliding_window else 16
    model_w = build_model(cfg, dtype=jnp.float32, decode_window=W)
    toks_w, stats_w = generate(model_w, params, prompts, gen_len=args.gen,
                               cache_len=W)
    print(f"window cache : {stats_w['tok_per_s']:7.1f} tok/s (W={W}), "
          f"first row {np.asarray(toks_w[0])[:8].tolist()}")


if __name__ == "__main__":
    main()

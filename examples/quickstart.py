"""Quickstart: one federated round of FedMeta w/ UGA on a reduced LM, CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FedConfig, get_smoke
from repro.core import init_server_state, make_federated_round
from repro.models.model import build_model

# 1. the federated learner: any assigned architecture (reduced variant here)
cfg = get_smoke("smollm-360m")
model = build_model(cfg, dtype=jnp.float32, loss_chunk=64)

# 2. the paper's algorithm knobs: UGA client updates + FedMeta server step
fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                client_lr=0.02, server_lr=0.02, meta_lr=0.02)

round_fn = jax.jit(make_federated_round(model, fed))
key = jax.random.PRNGKey(0)
state = init_server_state(model, fed, key)

# 3. synthetic client data: (cohort, per-client batch, seq+1) token ids
rng = np.random.default_rng(0)
cohort_batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (fed.cohort, 8, 65)), jnp.int32)}
meta_batch = {"tokens": jnp.asarray(
    rng.integers(0, cfg.vocab_size, (8, 65)), jnp.int32)}
weights = jnp.full((fed.cohort,), 8.0)

for r in range(5):
    state, metrics = round_fn(state, cohort_batch, meta_batch, weights,
                              jax.random.fold_in(key, r))
    print(f"round {r}: client_loss={float(metrics['client_loss']):.4f} "
          f"meta_loss={float(metrics['meta_loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.4f}")
print("OK — UGA keep-trace gradients aggregated unbiasedly, meta step applied")

"""Quickstart: FedMeta w/ UGA on a reduced LM through the plugin API, CPU.

The registries + one facade (see repro/core/__init__.py):

  * ClientAlgorithm  — what a client computes   (--algorithm uga/fednova/...)
  * CohortExecutor   — how the cohort runs      (vmap / scan / chunked /
                                                 sharded — all registrations
                                                 over one streaming core)
  * ServerEngine     — the server update        (legacy_tree / fused_flat)
  * MetricsTracker   — where round records go   (noop / console / jsonl /
                                                 csv / composite)
  * FederatedTrainer — the driver loop          (jit cache, chunking,
                                                 checkpoint/resume, history)

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import json
import os
import tempfile

import numpy as np
import jax.numpy as jnp

from repro.configs import FedConfig, get_smoke
from repro.core import (FederatedTrainer, available_algorithms,
                        available_engines, available_executors)
from repro.data.pipeline import FederatedData
from repro.models.model import build_model

# 1. the federated learner: any assigned architecture (reduced variant here)
cfg = get_smoke("smollm-360m")
model = build_model(cfg, dtype=jnp.float32, loss_chunk=64)

# 2. the paper's algorithm knobs — every name here is a registry lookup
print(f"algorithms: {available_algorithms()}")
print(f"executors:  {available_executors()}  engines: {available_engines()}")
fed = FedConfig(algorithm="uga", meta=True, cohort=4, local_steps=2,
                client_lr=0.02, server_lr=0.02, meta_lr=0.02)

# 3. synthetic client data: 8 clients of (n, seq+1) token ids + a D_meta set
rng = np.random.default_rng(0)
tokens = rng.integers(0, cfg.vocab_size, (256, 65)).astype(np.int32)
data = FederatedData(arrays={"tokens": tokens},
                     client_indices=[np.arange(i * 32, (i + 1) * 32)
                                     for i in range(8)],
                     meta_indices=rng.choice(256, 16, replace=False), seed=0)

# 4. five rounds through the facade (one record per round)
trainer = FederatedTrainer(model, fed, seed=0)
history = trainer.run(data, rounds=5, cohort=fed.cohort, batch=8,
                      meta_batch=8)
for rec in history:
    print(f"round {rec['round']}: client_loss={rec['client_loss']:.4f} "
          f"meta_loss={rec['meta_loss']:.4f} "
          f"grad_norm={rec['grad_norm']:.4f}")
print("OK — UGA keep-trace gradients aggregated unbiasedly, meta step "
      "applied, all through the algorithm/executor/engine registries")

# 5. communication compression (repro.comm, the fourth registry): an int8
# uplink with per-client error feedback is a 3-line change
fed_i8 = dataclasses.replace(fed, codec="int8", error_feedback=True,
                             fused_update=True)
rec = FederatedTrainer(model, fed_i8, seed=0).run(
    data, rounds=2, cohort=fed.cohort, batch=8, meta_batch=8)[-1]
print(f"int8+EF uplink: {rec['comm_bytes'] / 1e6:.2f} MB/round "
      f"(fp32 would ship ~4x), client_loss={rec['client_loss']:.4f}")

# 6. fault-tolerant async federation (repro.core.async_round + repro.sim):
# a flaky fleet feeding the buffered staleness-aware runtime is 3 lines
fed_async = dataclasses.replace(fed, engine="buffered_async", fused_update=True,
                                async_buffer=2, fault_profile="flaky")
rec = FederatedTrainer(model, fed_async, seed=0).run(
    data, rounds=3, cohort=fed.cohort, batch=8, meta_batch=8)[-1]
print(f"buffered async under faults: arrivals={rec['arrivals']:.0f} "
      f"server_steps={rec['server_steps']:.0f} "
      f"staleness_mean={rec['staleness_mean']:.2f} "
      f"client_loss={rec['client_loss']:.4f}")

# 7. big cohorts without big memory: cohort_chunk streams 16 clients at a
# time through the flat accumulators (the train.py flag is --cohort-chunk),
# so a 256-client round peaks at one chunk of gradients — the result is
# BITWISE the same at any chunk size (see BENCH_cohort_scaling.json for the
# cohort=1024 flat-memory numbers).  Same model, a 256-client fleet:
tokens_big = rng.integers(0, cfg.vocab_size, (512, 65)).astype(np.int32)
data_big = FederatedData(arrays={"tokens": tokens_big},
                         client_indices=[np.arange(i * 2, (i + 1) * 2)
                                         for i in range(256)], seed=0)
fed_chunk = dataclasses.replace(fed, cohort=256, cohort_chunk=16,
                                meta=False, fused_update=True)
rec = FederatedTrainer(model, fed_chunk, seed=0).run(
    data_big, rounds=1, cohort=256, batch=2)[-1]
print(f"chunked streaming: cohort=256 in 16-client chunks, "
      f"client_loss={rec['client_loss']:.4f}")

# 8. observability (repro.obs, the fifth registry): a jsonl tracker writes
# every round record + structured events (phase timing spans, run markers)
# to <run_dir>/metrics.jsonl without touching the numbers — a noop-tracked
# run is bit-identical to an untracked one (BENCH_obs_overhead.json)
run_dir = tempfile.mkdtemp(prefix="quickstart-obs-")
tr = FederatedTrainer(model, fed, seed=0, tracker="jsonl", run_dir=run_dir)
tr.run(data, rounds=2, cohort=fed.cohort, batch=8, meta_batch=8)
tr.finish()
with open(os.path.join(run_dir, "metrics.jsonl")) as f:
    lines = [json.loads(ln) for ln in f]
metric_lines = [ln for ln in lines if ln["kind"] == "metrics"]
phases = {ln["phase"] for ln in lines
          if ln["kind"] == "event" and ln["event"] == "phase"}
print(f"jsonl run dir: {len(metric_lines)} metric lines, "
      f"phases timed: {sorted(phases)}")
assert [m["client_loss"] for m in metric_lines] \
    == [h["client_loss"] for h in tr.history]

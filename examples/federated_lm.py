"""End-to-end driver: federated training of a language model with
FedMeta w/ UGA on synthetic non-IID client corpora, then serving it.

Default is a CPU-friendly reduced model; ``--hundred-m`` selects a ~110M
parameter llama-style learner (d_model 768, 12 layers) for a real run
(hours on CPU, minutes on a TPU slice), per the deliverable
"train a ~100M model for a few hundred steps".

    PYTHONPATH=src python examples/federated_lm.py [--rounds 200] [--hundred-m]
"""
import argparse

from repro.configs.base import ArchConfig
from repro.launch.train import run_training

HUNDRED_M = ArchConfig(
    name="fedlm-110m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32064,
    tie_embeddings=True, source="llama-style ~110M learner for the driver")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--algorithm", default="uga")
    ap.add_argument("--no-meta", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="artifacts/fedlm.msgpack")
    args = ap.parse_args()

    if args.hundred_m:
        from repro.configs import _MODULES  # register ad hoc
        import types
        mod = types.SimpleNamespace(CONFIG=HUNDRED_M, SMOKE=HUNDRED_M)
        _MODULES[HUNDRED_M.name] = mod
        arch = HUNDRED_M.name
        print(f"learner: {HUNDRED_M.name} "
              f"({HUNDRED_M.param_count()/1e6:.0f}M params)")
    else:
        arch = "smollm-360m-smoke"

    state, history = run_training(
        arch, rounds=args.rounds, cohort=4, client_batch=8, seq=args.seq,
        algorithm=args.algorithm, meta=not args.no_meta, local_steps=2,
        client_lr=0.01, num_clients=32, examples=1024, iid=False,
        ckpt_path=args.ckpt, log_every=5)
    first, last = history[0], history[-1]
    print(f"\nclient_loss {first['client_loss']:.4f} -> "
          f"{last['client_loss']:.4f} over {args.rounds} rounds")
    print(f"checkpoint: {args.ckpt} — serve it with:\n"
          f"  PYTHONPATH=src python -m repro.launch.serve --arch {arch} "
          f"--ckpt {args.ckpt} --batch 4 --prompt-len 32 --gen 16")


if __name__ == "__main__":
    main()
